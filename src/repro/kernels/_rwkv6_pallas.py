"""Pallas TPU kernel for the RWKV6 WKV chunked recurrence.

Same TPU shape as the SSD kernel: grid (B, H, chunks), the (D x D) per-head
state rides in VMEM scratch across sequential chunk steps.  Within a chunk the
token-vs-token decay matrix is built from cumulative log-decays and the three
matmuls (r_dec @ k_dec^T, scores @ v, k_carry^T @ v) hit the MXU.  Decays are
data-dependent per channel (Finch), so cum-logs are per (token, channel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, st0_ref, y_ref, stout_ref,
                state_ref, *, nc, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = st0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0, :].astype(jnp.float32)              # (Q, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    w = w_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)                       # (D,)
    state = state_ref[...]                                 # (D, D) k-major

    logw = jnp.log(jnp.maximum(w, 1e-30))
    cw = jnp.cumsum(logw, axis=0)                          # (Q, D) inclusive
    cw_prev = cw - logw                                    # exclusive
    r_dec = r * jnp.exp(cw_prev)
    k_dec = k * jnp.exp(-cw)
    scores = jax.lax.dot_general(r_dec, k_dec, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(jj < ii, scores, 0.0)               # strictly lower
    y = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # (Q,D)
    diag = jnp.sum(r * u[None, :] * k, axis=1)             # (Q,)
    y = y + diag[:, None] * v
    y = y + jax.lax.dot_general(r_dec, state, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    total = jnp.exp(cw[-1])                                # (D,)
    k_carry = k * jnp.exp(cw[-1][None, :] - cw)            # (Q, D)
    kv = jax.lax.dot_general(k_carry, v, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)      # (D,D)
    state_ref[...] = state * total[:, None] + kv

    @pl.when(ci == nc - 1)
    def _final():
        stout_ref[0, 0] = state_ref[...].astype(stout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "return_state", "interpret"))
def wkv6_pallas(r, k, v, w, u, *, chunk=128, init_state=None,
                return_state=False, interpret=False):
    """Contract identical to kernels/ref.py::wkv6."""
    B, S, H, D = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    if init_state is None:
        init_state = jnp.zeros((B, H, D, D), jnp.float32)

    kernel = functools.partial(_wkv_kernel, nc=nc, chunk=chunk)
    y, stout = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, D), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, D), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, D), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, D), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, D), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, D), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, D), r.dtype),
            jax.ShapeDtypeStruct((B, H, D, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, init_state)
    if return_state:
        return y, stout
    return y
