"""A miniature Slurm for integration tests — real subprocesses, real signals.

Reproduces the scheduler behaviours the paper's workflow (Fig. 3) depends on:
  * walltime limits with an advance-warning signal (``--signal=B:USR1@60``):
    jobs get ``warn_signal`` ``signal_margin_s`` before the limit, then SIGKILL;
  * requeue on preemption / timeout / exit code 85 (REQUEUE_EXIT), appending
    output (``open(..., "ab")`` — the paper's append-mode logging);
  * manual preemption (``scancel``-style) for tests;
  * a job comment file tracking consumed walltime across requeues.

The "cluster" is this machine; each job is one subprocess (one worker of the
framework, or a whole single-process training run).
"""
from __future__ import annotations

import dataclasses
import os
import signal
import subprocess
import time
from pathlib import Path
from typing import Optional

REQUEUE_EXIT = 85     # exit code meaning "checkpointed, please requeue"


@dataclasses.dataclass
class JobSpec:
    name: str
    cmd: list
    walltime_s: float
    signal_margin_s: float = 5.0
    warn_signal: int = signal.SIGUSR1
    requeue: bool = True
    max_requeues: int = 10
    env: Optional[dict] = None
    cwd: Optional[str] = None


@dataclasses.dataclass
class JobRecord:
    job_id: int
    spec: JobSpec
    state: str = "PENDING"          # PENDING RUNNING COMPLETED FAILED REQUEUED
    requeues: int = 0
    exit_codes: list = dataclasses.field(default_factory=list)
    started_at: float = 0.0
    warned: bool = False
    proc: Optional[subprocess.Popen] = None
    preempt_requested: bool = False


class SlurmSim:
    def __init__(self, workdir: Path, poll_s: float = 0.05):
        self.workdir = Path(workdir)
        self.workdir.mkdir(parents=True, exist_ok=True)
        self.poll_s = poll_s
        self._jobs: dict[int, JobRecord] = {}
        self._next_id = 1000

    # ------------------------------------------------------------------
    def submit(self, spec: JobSpec) -> int:
        jid = self._next_id
        self._next_id += 1
        self._jobs[jid] = JobRecord(job_id=jid, spec=spec)
        return jid

    def job(self, jid: int) -> JobRecord:
        return self._jobs[jid]

    def preempt(self, jid: int) -> None:
        """scancel-with-requeue: deliver SIGTERM now; job should checkpoint+exit."""
        rec = self._jobs[jid]
        rec.preempt_requested = True
        if rec.proc and rec.proc.poll() is None:
            rec.proc.send_signal(signal.SIGTERM)

    # ------------------------------------------------------------------
    def _launch(self, rec: JobRecord) -> None:
        spec = rec.spec
        out = self.workdir / f"{spec.name}.out"
        env = dict(os.environ)
        env.update(spec.env or {})
        env["SLURM_JOB_ID"] = str(rec.job_id)
        env["SLURM_RESTART_COUNT"] = str(rec.requeues)
        with open(out, "ab") as fh:                      # append across requeues
            fh.write(f"\n=== launch attempt {rec.requeues} ===\n".encode())
            fh.flush()
            rec.proc = subprocess.Popen(
                spec.cmd, stdout=fh, stderr=subprocess.STDOUT,
                env=env, cwd=spec.cwd)
        rec.state = "RUNNING"
        rec.started_at = time.monotonic()
        rec.warned = False

    def _tick(self, rec: JobRecord) -> None:
        if rec.state != "RUNNING":
            return
        proc = rec.proc
        assert proc is not None
        code = proc.poll()
        spec = rec.spec
        elapsed = time.monotonic() - rec.started_at
        if code is None:
            if (not rec.warned
                    and elapsed >= spec.walltime_s - spec.signal_margin_s):
                proc.send_signal(spec.warn_signal)
                rec.warned = True
            if elapsed >= spec.walltime_s:
                proc.kill()                               # hard limit
            return
        rec.exit_codes.append(code)
        should_requeue = spec.requeue and rec.requeues < spec.max_requeues and (
            code == REQUEUE_EXIT or code == -signal.SIGKILL
            or (rec.preempt_requested and code != 0))
        if code == 0:
            rec.state = "COMPLETED"
        elif should_requeue:
            rec.requeues += 1
            rec.preempt_requested = False
            rec.state = "PENDING"                         # back to the queue
        else:
            rec.state = "FAILED"

    def run(self, timeout_s: float = 600.0) -> None:
        """Event loop until every job is COMPLETED or FAILED."""
        t0 = time.monotonic()
        while True:
            pending_done = True
            for rec in self._jobs.values():
                if rec.state == "PENDING":
                    self._launch(rec)
                self._tick(rec)
                if rec.state in ("PENDING", "RUNNING"):
                    pending_done = False
            if pending_done:
                return
            if time.monotonic() - t0 > timeout_s:
                for rec in self._jobs.values():
                    if rec.proc and rec.proc.poll() is None:
                        rec.proc.kill()
                raise TimeoutError("slurmsim timeout")
            time.sleep(self.poll_s)

    def states(self) -> dict:
        return {j: r.state for j, r in self._jobs.items()}
