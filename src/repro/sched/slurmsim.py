"""A miniature Slurm for integration tests — real subprocesses, real signals.

Reproduces the scheduler behaviours the paper's workflow (Fig. 3) depends on:
  * walltime limits with an advance-warning signal (``--signal=B:USR1@60``):
    jobs get ``warn_signal`` ``signal_margin_s`` before the limit, then SIGKILL;
  * requeue on preemption / timeout / exit code 85 (REQUEUE_EXIT), appending
    output (``open(..., "ab")`` — the paper's append-mode logging);
  * manual preemption (``scancel``-style) for tests;
  * a job comment file tracking consumed walltime across requeues (the
    paper's ``--comment`` accounting — survives even a fresh SlurmSim).
    Accounting is keyed by job NAME so a resubmission resumes its budget;
    reuse a name only for resubmissions, never for concurrent unrelated
    jobs in one workdir;
  * a small multi-node cluster model with restore-aware placement: each
    ``NodeSpec`` owns a node-local tier root, and a requeued job with a
    ``cache_affinity`` is preferentially placed on the node whose promoted
    checkpoint cache is warm for its latest committed step (the paper's
    container-image-cache effect, scheduler-side), with a bounded
    wait-for-warm-node policy before falling back to any free node — and a
    job that ends up on a COLD node is handed the warm nodes as a peer hint
    (``REPRO_PEER_ROOTS``) so its restore sources the checkpoint from a warm
    peer's local cache instead of the shared filesystem.

The "cluster" is this machine; each node is a directory (its local tier
root), each job one subprocess.  Jobs learn their placement through
``SLURMSIM_NODE`` / ``SLURMD_NODENAME`` and mount the node's local tier via
``REPRO_LOCAL_ROOT`` (see launch/train.py).
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import subprocess
import time
from pathlib import Path
from typing import Callable, Optional

from repro.sched import cache_registry as CR
from repro.sched import placement as PL

REQUEUE_EXIT = 85     # exit code meaning "checkpointed, please requeue"


@dataclasses.dataclass
class NodeSpec:
    """One cluster node: a name, a job capacity (``slots=0`` = unlimited —
    the single-machine mode), and a node-local filesystem root (the per-node
    ``local``/``ram`` tier mount — promotion caches land here)."""

    name: str
    slots: int = 1
    local_root: Optional[Path] = None


@dataclasses.dataclass
class JobSpec:
    name: str
    cmd: list
    walltime_s: float
    signal_margin_s: float = 5.0
    warn_signal: int = signal.SIGUSR1
    requeue: bool = True
    max_requeues: int = 10
    env: Optional[dict] = None
    cwd: Optional[str] = None
    cache_affinity: Optional[PL.CacheAffinity] = None


@dataclasses.dataclass
class JobRecord:
    job_id: int
    spec: JobSpec
    state: str = "PENDING"          # PENDING RUNNING COMPLETED FAILED REQUEUED
    requeues: int = 0
    exit_codes: list = dataclasses.field(default_factory=list)
    started_at: float = 0.0
    warned: bool = False
    proc: Optional[subprocess.Popen] = None
    preempt_requested: bool = False
    node: Optional[str] = None              # current / last placement
    consumed_s: float = 0.0                 # walltime across all attempts
    pending_since: float = 0.0              # for the bounded warm-node wait
    placements: list = dataclasses.field(default_factory=list)
    placement_log: list = dataclasses.field(default_factory=list)
    peer_hint: dict = dataclasses.field(default_factory=dict)  # node -> root


class SlurmSim:
    """``nodes`` may be an int (that many one-slot nodes, local roots under
    ``workdir/nodes/``) or a list of ``NodeSpec``.  ``placement`` selects the
    policy: ``"affinity"`` (restore-aware scoring via ``sched/placement.py``)
    or ``"blind"`` (round-robin by attempt — the baseline the benchmarks and
    tests compare against).  ``pre_launch(rec)`` runs right before every
    launch attempt — the fault-injection hook the chaos harness uses to
    corrupt caches at exact requeue boundaries."""

    def __init__(self, workdir: Path, poll_s: float = 0.05,
                 nodes: int | list[NodeSpec] | None = None,
                 placement: str = "affinity",
                 pre_launch: Optional[Callable[["JobRecord"], None]] = None):
        assert placement in ("affinity", "blind")
        self.workdir = Path(workdir)
        self.workdir.mkdir(parents=True, exist_ok=True)
        self.poll_s = poll_s
        self.placement = placement
        self.pre_launch = pre_launch
        if nodes is None:
            # legacy single-machine mode: one node, unlimited slots, so every
            # pending job still launches concurrently as before the cluster
            # model existed
            nodes = [NodeSpec("node0", slots=0)]
        if isinstance(nodes, int):
            nodes = [NodeSpec(f"node{i}") for i in range(nodes)]
        self.nodes: list[NodeSpec] = []
        for nd in nodes:
            if nd.local_root is None:
                nd = dataclasses.replace(
                    nd, local_root=self.workdir / "nodes" / nd.name)
            nd.local_root = Path(nd.local_root)
            nd.local_root.mkdir(parents=True, exist_ok=True)
            self.nodes.append(nd)
        self._busy: dict[str, int] = {nd.name: 0 for nd in self.nodes}
        self._jobs: dict[int, JobRecord] = {}
        self._hooked: set = set()           # (job_id, attempt) already hooked
        # cache-probe results while a job waits for a busy warm node: the
        # poll loop calls _place every poll_s, and probing every node's
        # marker/manifest/file sizes each tick would hammer the shared
        # filesystem for information that only changes when checkpoints do
        self.probe_ttl_s = 1.0
        self._probes: dict[int, tuple[int, float, dict]] = {}
        self._next_id = 1000

    # ------------------------------------------------------------------
    def submit(self, spec: JobSpec) -> int:
        jid = self._next_id
        self._next_id += 1
        rec = JobRecord(job_id=jid, spec=spec,
                        pending_since=time.monotonic())
        # the comment file outlives the scheduler: a resubmitted job resumes
        # its consumed-walltime accounting (the paper's --comment round-trip)
        prior = self._read_comment(spec.name)
        rec.consumed_s = float(prior.get("consumed_s", 0.0))
        self._jobs[jid] = rec
        return jid

    def job(self, jid: int) -> JobRecord:
        return self._jobs[jid]

    def node(self, name: str) -> NodeSpec:
        return next(nd for nd in self.nodes if nd.name == name)

    def preempt(self, jid: int) -> None:
        """scancel-with-requeue: deliver SIGTERM now; job should checkpoint+exit."""
        rec = self._jobs[jid]
        rec.preempt_requested = True
        if rec.proc and rec.proc.poll() is None:
            rec.proc.send_signal(signal.SIGTERM)

    # -- comment file (paper --comment walltime accounting) -------------
    def _comment_path(self, name: str) -> Path:
        return self.workdir / f"{name}.comment"

    def _read_comment(self, name: str) -> dict:
        try:
            return json.loads(self._comment_path(name).read_text())
        except (FileNotFoundError, ValueError, OSError):
            return {}

    def _write_comment(self, rec: JobRecord) -> None:
        p = self._comment_path(rec.spec.name)
        tmp = p.with_suffix(p.suffix + ".tmp")
        tmp.write_text(json.dumps({
            "consumed_s": rec.consumed_s,
            "requeues": rec.requeues,
            "placements": rec.placements,
            "state": rec.state,
        }))
        tmp.rename(p)

    # -- placement ------------------------------------------------------
    def _free(self, nd: NodeSpec) -> bool:
        return nd.slots == 0 or self._busy[nd.name] < nd.slots

    def _place(self, rec: JobRecord) -> Optional[NodeSpec]:
        """Pick a node for a PENDING job, or None to keep it queued.

        Affinity policy: score every node (warm promoted cache > requeue-hint
        > cold; sched/placement.py) and take the best FREE one — unless a
        busy node scores strictly higher and the job's ``warm_wait_s`` budget
        has not run out, in which case the job waits (bounded) for the warm
        node to drain.  Blind policy: round-robin by attempt number.
        """
        free = [nd for nd in self.nodes if self._free(nd)]
        aff = rec.spec.cache_affinity
        if not free:
            return None
        if aff is None or self.placement == "blind":
            want = self.nodes[rec.requeues % len(self.nodes)]
            chosen = want if self._free(want) else free[0]
            rec.peer_hint = {}              # blind baseline: no fabric help
            rec.placement_log.append({
                "attempt": rec.requeues, "node": chosen.name,
                "policy": "blind", "scores": None,
                "waited_s": time.monotonic() - rec.pending_since})
            return chosen
        now = time.monotonic()
        cached = self._probes.get(rec.job_id)
        if (cached is not None and cached[0] == rec.requeues
                and now - cached[1] <= self.probe_ttl_s):
            ranked = cached[2]
        else:
            ranked = PL.rank_nodes(
                [(nd.name, nd.local_root) for nd in self.nodes], aff,
                last_node=rec.node)
            self._probes[rec.job_id] = (rec.requeues, now, ranked)
        best_free = max(free, key=lambda nd: ranked[nd.name]["score"])
        best_any = max(self.nodes, key=lambda nd: ranked[nd.name]["score"])
        waited = time.monotonic() - rec.pending_since
        if (ranked[best_any.name]["score"] > ranked[best_free.name]["score"]
                and waited < aff.warm_wait_s):
            return None                     # bounded wait for the warm node
        # the peer hint: every OTHER warm node, handed to the job so a
        # cold placement restores from a warm peer's cache, not the shared FS
        rec.peer_hint = PL.warm_peer_roots(
            [(nd.name, nd.local_root) for nd in self.nodes], ranked,
            exclude=(best_free.name,))
        rec.placement_log.append({
            "attempt": rec.requeues, "node": best_free.name,
            "policy": "affinity",
            "scores": {n: r["score"] for n, r in ranked.items()},
            "reasons": {n: r["probe"]["reason"] for n, r in ranked.items()},
            "peers": sorted(rec.peer_hint),
            "waited_s": waited})
        return best_free

    # ------------------------------------------------------------------
    def _launch(self, rec: JobRecord, node: NodeSpec) -> None:
        spec = rec.spec
        out = self.workdir / f"{spec.name}.out"
        env = dict(os.environ)
        env.update(spec.env or {})
        env["SLURM_JOB_ID"] = str(rec.job_id)
        env["SLURM_RESTART_COUNT"] = str(rec.requeues)
        env["SLURMSIM_NODE"] = node.name
        env["SLURMD_NODENAME"] = node.name
        env["REPRO_LOCAL_ROOT"] = str(node.local_root)
        if rec.peer_hint:
            env[CR.ENV_PEER_ROOTS] = CR.format_peer_roots(rec.peer_hint)
        else:
            env.pop(CR.ENV_PEER_ROOTS, None)
        with open(out, "ab") as fh:                      # append across requeues
            fh.write(f"\n=== launch attempt {rec.requeues} "
                     f"on {node.name} ===\n".encode())
            fh.flush()
            rec.proc = subprocess.Popen(
                spec.cmd, stdout=fh, stderr=subprocess.STDOUT,
                env=env, cwd=spec.cwd)
        rec.state = "RUNNING"
        rec.node = node.name
        rec.placements.append(node.name)
        self._busy[node.name] += 1
        rec.started_at = time.monotonic()
        rec.warned = False

    def _tick(self, rec: JobRecord) -> None:
        if rec.state != "RUNNING":
            return
        proc = rec.proc
        assert proc is not None
        code = proc.poll()
        spec = rec.spec
        elapsed = time.monotonic() - rec.started_at
        if code is None:
            if (not rec.warned
                    and elapsed >= spec.walltime_s - spec.signal_margin_s):
                proc.send_signal(spec.warn_signal)
                rec.warned = True
            if elapsed >= spec.walltime_s:
                proc.kill()                               # hard limit
            return
        rec.exit_codes.append(code)
        rec.consumed_s += elapsed
        if rec.node is not None:
            self._busy[rec.node] -= 1
        should_requeue = spec.requeue and rec.requeues < spec.max_requeues and (
            code == REQUEUE_EXIT or code == -signal.SIGKILL
            or (rec.preempt_requested and code != 0))
        if code == 0:
            rec.state = "COMPLETED"
        elif should_requeue:
            rec.requeues += 1
            rec.preempt_requested = False
            rec.state = "PENDING"                         # back to the queue
            rec.pending_since = time.monotonic()
        else:
            rec.state = "FAILED"
        if rec.state in ("COMPLETED", "FAILED"):   # per-job bookkeeping done
            self._probes.pop(rec.job_id, None)
            self._hooked = {k for k in self._hooked if k[0] != rec.job_id}
        self._write_comment(rec)

    def run(self, timeout_s: float = 600.0) -> None:
        """Event loop until every job is COMPLETED or FAILED."""
        t0 = time.monotonic()
        while True:
            pending_done = True
            for rec in self._jobs.values():
                if rec.state == "PENDING":
                    # the fault hook fires BEFORE the placement probe (once
                    # per attempt) so injected cache damage is what the
                    # scheduler's scoring actually sees
                    key = (rec.job_id, rec.requeues)
                    if self.pre_launch is not None and key not in self._hooked:
                        self._hooked.add(key)
                        self.pre_launch(rec)
                    node = self._place(rec)
                    if node is not None:
                        self._launch(rec, node)
                self._tick(rec)
                if rec.state in ("PENDING", "RUNNING"):
                    pending_done = False
            if pending_done:
                return
            if time.monotonic() - t0 > timeout_s:
                for rec in self._jobs.values():
                    if rec.proc and rec.proc.poll() is None:
                        rec.proc.kill()
                raise TimeoutError("slurmsim timeout")
            time.sleep(self.poll_s)

    def states(self) -> dict:
        return {j: r.state for j, r in self._jobs.items()}
