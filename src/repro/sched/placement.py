"""Restore-aware placement scoring — prefer nodes holding a warm promoted cache.

The paper's restart cost is dominated by re-reading checkpoint and
container-image bytes from the shared filesystem; NERSC's Shifter/Podman-HPC
image caches make a SAME-NODE restart cheap.  PR 2 built the framework
analogue (shared->local promotion with a two-phase ``PROMOTED.json`` marker);
this module teaches the scheduler to exploit it: on requeue, probe every
candidate node's local tier and prefer the one whose promoted cache is warm
for the job's latest committed step.

Scoring (``rank_nodes``):

  SCORE_WARM (2)  node's ``PROMOTED.json`` validates against the latest
                  committed step (invalidation/truncation-aware — see
                  ``checkpoint.manager.validate_promoted_cache``);
  SCORE_HINT (1)  node matches the requeue record's last placement
                  (``<ckpt_dir>/requeue.json`` written by the job via
                  ``core/requeue.py``) — the OS page/container-image cache
                  may still be warm even when no promotion ran;
  SCORE_COLD (0)  everything else.

When the best free node is NOT warm (contention, or the warm node's
``warm_wait_s`` budget ran out), the scheduler no longer just eats the cold
restore: ``warm_peer_roots`` turns the same probe results into a peer hint —
the other nodes whose promoted caches validated warm — which the launcher
hands to the job (``REPRO_PEER_ROOTS``) so its restore engine sources ranges
from a warm peer's local tier instead of the shared filesystem (see
sched/cache_registry.py and checkpoint/restore_engine.py).

Placement is strictly advisory: a wrong pick costs shared-filesystem reads,
never correctness — stale caches are rejected at probe time AND again (CRC
pinned) in the restore path.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Optional

from repro.checkpoint.manager import committed_steps, validate_promoted_cache
from repro.checkpoint.store import TieredStore

SCORE_WARM = 2
SCORE_HINT = 1
SCORE_COLD = 0


@dataclasses.dataclass
class CacheAffinity:
    """How the scheduler probes a job's checkpoint caches.

    ``ckpt_dir`` is the job's TieredStore root (the shared tier and the
    requeue record live under it); each candidate node's local tier is
    mounted at that node's ``local_root``.  ``warm_wait_s`` bounds how long a
    requeued job may stay PENDING waiting for a busy warm node before it
    falls back to any free node (0 = never wait).
    """

    ckpt_dir: str
    prefix: str = "ckpt"
    tier: str = "shared"
    promote_tier: str = "local"
    warm_wait_s: float = 0.0

    def requeue_record(self) -> dict:
        try:
            return json.loads(
                (Path(self.ckpt_dir) / "requeue.json").read_text())
        except (FileNotFoundError, ValueError, OSError):
            return {}


def probe_cache(aff: CacheAffinity, local_root: Path,
                latest: Optional[int] = None) -> dict:
    """Validate one node's promoted cache for ``aff``'s checkpoint prefix.
    Builds a store view whose promote tier is rooted at the node.  Pass
    ``latest`` when probing many nodes so the (node-independent) shared-tier
    step listing is done once, not per node."""
    store = TieredStore(Path(aff.ckpt_dir),
                        tier_roots={aff.promote_tier: Path(local_root)})
    return validate_promoted_cache(store, tier=aff.tier,
                                   promote_tier=aff.promote_tier,
                                   prefix=aff.prefix, latest=latest)


def rank_nodes(candidates: list[tuple[str, Path]],
               aff: CacheAffinity,
               last_node: Optional[str] = None) -> dict[str, dict]:
    """Score every candidate ``(name, local_root)``.  Returns
    ``{name: {"score": int, "probe": dict|None}}`` — the scheduler picks the
    highest-scoring free node (submission order breaks ties).
    """
    if last_node is None:
        last_node = aff.requeue_record().get("node")
    # the shared tier is one filesystem for every node: list its committed
    # steps once, not once per candidate
    steps = committed_steps(TieredStore(Path(aff.ckpt_dir)),
                            aff.tier, aff.prefix)
    latest = steps[-1] if steps else None
    out: dict[str, dict] = {}
    for name, local_root in candidates:
        probe = probe_cache(aff, local_root, latest=latest)
        if probe["valid"]:
            score = SCORE_WARM
        elif last_node is not None and name == last_node:
            score = SCORE_HINT
        else:
            score = SCORE_COLD
        out[name] = {"score": score, "probe": probe}
    return out


def warm_peer_roots(candidates: list[tuple[str, Path]],
                    ranked: dict[str, dict],
                    exclude: tuple = ()) -> dict[str, str]:
    """The peer hint for a job placed on a cold node: every candidate whose
    promoted cache probed warm, minus ``exclude`` (the chosen node), as
    ``{node: local_root}`` ready for ``cache_registry.format_peer_roots``.
    Advisory like every probe — the job re-validates each peer's marker and
    pins manifest CRCs before trusting a single payload byte."""
    ex = set(exclude)
    return {name: str(root) for name, root in candidates
            if name not in ex
            and (ranked.get(name) or {}).get("probe", {}).get("valid")}
