"""Cluster-wide inventory of warm promoted checkpoint caches (peer fabric).

The scheduler's placement probe (sched/placement.py) answers "is THIS node
warm?"; the peer fabric needs the transpose — "which OTHER nodes are warm for
step N, and where do their caches mount?" — so a job placed on a cold node
can source its restore from a warm peer's local tier instead of the shared
parallel filesystem (the DMTCP cluster story: peers cooperate on restart).

The registry is one tiny JSON file per node under a shared directory
(default ``<ckpt_dir>/peer_registry/<node>.json``), written atomically
(tmp + rename) by ``CheckpointManager`` when a promotion COMMITS (after the
two-phase ``PROMOTED.json`` marker is published) and withdrawn whenever the
node invalidates its cache.  Entry schema:

    {"node": "node3", "step": 41, "files": ["ckpt/step_.../shard_...bin"...],
     "local_root": "/.../nodes/node3", "tier": "local", "published_at": ...}

Readers treat the inventory as strictly ADVISORY: a torn entry reads as
absent, a ``step`` mismatch is stale and skipped, and even a lying entry (the
peer died between GC'ing its cache and withdrawing) only costs a per-range
fallback — the restore path re-checks the peer's marker, pins manifest CRCs,
and falls back to the next peer or the shared tier on any failure, so a stale
inventory entry is never *served*.

``REPRO_PEER_ROOTS`` (``name=root,name=root``) is the same information on the
scheduler -> job wire: SlurmSim computes warm peers from its own placement
probes and hands them to the launched process, which merges them with
whatever the registry holds.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Iterable, Optional

from repro.utils.atomic import atomic_write_json

ENV_PEER_ROOTS = "REPRO_PEER_ROOTS"
REGISTRY_DIRNAME = "peer_registry"
FOLLOWER_DIRNAME = "followers"


def format_peer_roots(peers: dict) -> str:
    """``{name: root}`` -> the ``name=root,name=root`` env/CLI encoding."""
    return ",".join(f"{n}={p}" for n, p in sorted(peers.items()))


def parse_peer_roots(raw: Optional[str]) -> dict[str, Path]:
    """Parse the ``name=root,name=root`` encoding (env var or ``--peer-roots``
    flag); malformed fragments are dropped, not fatal — a mangled hint must
    degrade to a cold restore, never kill the restart."""
    out: dict[str, Path] = {}
    for part in (raw or "").split(","):
        name, sep, root = part.strip().partition("=")
        if name and sep and root:
            out[name] = Path(root)
    return out


class CacheRegistry:
    """Per-node warm-cache inventory under one shared directory."""

    def __init__(self, root: Path):
        self.root = Path(root)

    def _path(self, node: str) -> Path:
        return self.root / f"{node}.json"

    def _atomic_write(self, p: Path, obj: dict) -> None:
        """Atomic JSON publish with a UNIQUE tmp name — the shared
        ``utils.atomic`` contract (see that module for why a fixed
        ``<name>.json.tmp`` path would tear under concurrent writers of
        the same key)."""
        atomic_write_json(p, obj)

    def publish(self, node: str, *, step: int, files: Iterable[str],
                local_root, tier: str = "local",
                baseline_step: Optional[int] = None,
                chunk_count: Optional[int] = None) -> dict:
        """Record that ``node`` holds a validated promoted cache of ``step``
        under ``local_root`` (atomic tmp + rename, so a concurrent reader
        sees the old entry or the new one, never a torn one).

        Delta-aware entries additionally advertise the chunk inventory: for
        a chunked (v3) cache, ``files`` already lists the content-addressed
        chunk paths, and ``baseline_step``/``chunk_count`` tell readers the
        cache's delta-chain baseline and how many chunks it holds — what a
        cold node's planner needs to decide that a STALE peer is still worth
        sourcing from (most chunks survive across nearby steps)."""
        entry = {
            "node": node,
            "step": int(step),
            "files": sorted(files),
            "local_root": str(local_root),
            "tier": tier,
            "published_at": time.time(),
        }
        if baseline_step is not None:
            entry["baseline_step"] = int(baseline_step)
        if chunk_count is not None:
            entry["chunk_count"] = int(chunk_count)
        self._atomic_write(self._path(node), entry)
        return entry

    def withdraw(self, node: str) -> None:
        """Drop ``node``'s entry (its cache was invalidated or GC'd)."""
        self._path(node).unlink(missing_ok=True)

    # -- follower caches (serving fleet, replica-to-replica) -------------
    # A serving replica that finishes a weight sync holds every chunk of
    # the synced step in its node-local tier (its own stale promoted cache
    # plus the delta the fetch teed in) WITHOUT owning the node's
    # ``PROMOTED.json`` — it is a read-only follower, the marker may belong
    # to another consumer on the node.  These entries advertise that
    # inventory as a chunk-only peer source: replica N+1 pulls the delta
    # from replica N instead of the shared tier, so fleet-wide shared-tier
    # bytes stay ~one delta however large the fleet.  Chunk-only means
    # readers must never plan shard files or manifests against them —
    # ``near_peers`` folds them in, ``warm_peers`` (the shard fabric's
    # source) never does.

    def _follower_path(self, node: str) -> Path:
        return self.root / FOLLOWER_DIRNAME / f"{node}.json"

    def publish_follower(self, node: str, *, step: int, local_root,
                         tier: str = "local",
                         baseline_step: Optional[int] = None,
                         chunk_count: Optional[int] = None) -> dict:
        """Record that follower ``node`` holds all chunks of ``step`` under
        ``local_root`` (one file per node under ``followers/``, atomic,
        superseded by the node's next sync).  Advisory like every entry:
        the chunk plane re-pins manifest CRCs per chunk, so a lying or GC'd
        follower cache costs a per-chunk fallback, never wrong bytes."""
        entry = {
            "node": node,
            "step": int(step),
            "kind": "follower",
            "local_root": str(local_root),
            "tier": tier,
            "published_at": time.time(),
        }
        if baseline_step is not None:
            entry["baseline_step"] = int(baseline_step)
        if chunk_count is not None:
            entry["chunk_count"] = int(chunk_count)
        self._atomic_write(self._follower_path(node), entry)
        return entry

    def withdraw_follower(self, node: str) -> None:
        """Drop ``node``'s follower-cache entry (its local tier was
        invalidated, or the replica left the fleet)."""
        self._follower_path(node).unlink(missing_ok=True)

    def follower_entries(self) -> dict[str, dict]:
        """All parseable follower-cache entries, keyed by node (same torn-
        file tolerance as ``entries``)."""
        out: dict[str, dict] = {}
        fdir = self.root / FOLLOWER_DIRNAME
        if not fdir.is_dir():
            return out
        for p in sorted(fdir.glob("*.json")):
            try:
                e = json.loads(p.read_text())
            except (ValueError, OSError):
                continue
            if (isinstance(e, dict) and e.get("node")
                    and isinstance(e.get("step"), int)
                    and e.get("local_root")):
                e.setdefault("kind", "follower")
                out[e["node"]] = e
        return out

    def entries(self) -> dict[str, dict]:
        """All parseable entries, keyed by node.  Torn/malformed files read
        as absent — the writer is atomic, but a reader must survive anything
        a crashed peer left behind."""
        out: dict[str, dict] = {}
        if not self.root.is_dir():
            return out
        for p in sorted(self.root.glob("*.json")):
            try:
                e = json.loads(p.read_text())
            except (ValueError, OSError):
                continue
            if (isinstance(e, dict) and e.get("node")
                    and isinstance(e.get("step"), int)
                    and e.get("local_root")):
                out[e["node"]] = e
        return out

    def warm_peers(self, step: int, exclude: Iterable[Optional[str]] = ()
                   ) -> dict[str, dict]:
        """Entries claiming a warm cache of exactly ``step``, minus
        ``exclude`` (normally the asking node itself).  Advisory — the
        restore path re-validates every peer before reading payload."""
        ex = {n for n in exclude if n}
        return {n: e for n, e in self.entries().items()
                if e["step"] == int(step) and n not in ex}

    def near_peers(self, step: int, exclude: Iterable[Optional[str]] = (),
                   max_lag: Optional[int] = None,
                   include_followers: bool = True) -> dict[str, dict]:
        """Chunk-capable peer entries for ``step``: promoted caches of some
        OTHER step — stale for the shard fabric, but a chunk-plane (delta)
        restore resolves by content hash, so these peers still serve every
        chunk shared with the target step — plus (by default) follower-
        cache entries at ANY step within ``max_lag``, including exactly
        ``step``: a follower that synced the target step serves its whole
        delta, but only chunk-wise (no marker, no manifest), so even an
        exact-step follower belongs here and never in ``warm_peers``.
        Ordered nearest-step-first (the closer the cached step, the larger
        the expected chunk overlap), a node's nearest entry winning when it
        has both kinds.  Advisory, like everything here."""
        ex = {n for n in exclude if n}
        step = int(step)
        cands = [(abs(e["step"] - step), n, e)
                 for n, e in self.entries().items()
                 if e["step"] != step and n not in ex]
        if include_followers:
            cands += [(abs(e["step"] - step), n, e)
                      for n, e in self.follower_entries().items()
                      if n not in ex]
        out: dict[str, dict] = {}
        for lag, n, e in sorted(cands, key=lambda c: (c[0], c[1])):
            if n not in out and (max_lag is None or lag <= max_lag):
                out[n] = e
        return out

    # -- weight-push plane (serving fleet) ------------------------------
    # The publisher (a fine-tune/RLHF trainer) announces each committed
    # step; serving replicas poll the announcement to learn that a newer
    # step exists WITHOUT listing the checkpoint prefix (one tiny JSON read
    # per poll, whatever the fleet size), and publish their own sync state
    # back so operators/schedulers can see fleet-wide lag in one listing.
    # Same durability story as the cache entries: atomic writes, advisory
    # reads — a replica that trusts a torn announcement merely polls again.

    def _push_path(self) -> Path:
        return self.root / "PUSH.json"

    def announce_push(self, *, step: int, node: Optional[str] = None,
                      manifest_version: Optional[int] = None,
                      meta: Optional[dict] = None) -> dict:
        """Publisher-side: advertise that ``step`` is committed and ready
        for the fleet to pull (called after ``CheckpointManager.commit``
        — the commit marker, not this announcement, is what makes the step
        restorable; the announcement only saves followers the listing)."""
        ann = {"step": int(step), "announced_at": time.time()}
        if node:
            ann["node"] = node
        if manifest_version is not None:
            ann["manifest_version"] = int(manifest_version)
        if meta:
            ann["meta"] = meta
        self._atomic_write(self._push_path(), ann)
        return ann

    def latest_push(self) -> Optional[dict]:
        """Subscriber-side poll: the newest announcement, or None (absent
        or torn — the follower keeps serving its current weights)."""
        try:
            ann = json.loads(self._push_path().read_text())
        except (FileNotFoundError, ValueError, OSError):
            return None
        if isinstance(ann, dict) and isinstance(ann.get("step"), int):
            return ann
        return None

    def _replica_path(self, replica: str) -> Path:
        return self.root / "replicas" / f"{replica}.json"

    def publish_replica(self, replica: str, *, step: Optional[int],
                        target_step: Optional[int] = None,
                        phase: str = "serving",
                        stats: Optional[dict] = None) -> dict:
        """Replica-side: record this serving replica's sync state (current
        ``step``, the ``target_step`` it is converging to, a ``phase`` like
        ``serving``/``fetching``/``swapping``/``stalled``, and the last
        sync's fetch/swap stats).  One file per replica, atomic."""
        entry = {
            "replica": replica,
            "step": step,
            "phase": phase,
            "updated_at": time.time(),
        }
        if target_step is not None:
            entry["target_step"] = int(target_step)
        if stats:
            entry["stats"] = stats
        self._atomic_write(self._replica_path(replica), entry)
        return entry

    def replica_status(self) -> dict[str, dict]:
        """Fleet view: every parseable replica entry, keyed by replica name,
        each annotated with ``lag`` (latest announced step minus the
        replica's step; None when either side is unknown)."""
        out: dict[str, dict] = {}
        rdir = self.root / "replicas"
        if not rdir.is_dir():
            return out
        ann = self.latest_push()
        latest = ann["step"] if ann else None
        for p in sorted(rdir.glob("*.json")):
            try:
                e = json.loads(p.read_text())
            except (ValueError, OSError):
                continue
            if not (isinstance(e, dict) and e.get("replica")):
                continue
            # clamped at 0 like WeightSyncClient.lag(): a replica AHEAD of
            # the announcement (stale/torn PUSH.json, or it restored a step
            # the publisher has not announced yet) is current, not
            # negatively lagged — dashboards must agree with the replica's
            # own staleness gate
            e["lag"] = (max(0, latest - e["step"])
                        if latest is not None and isinstance(e.get("step"), int)
                        else None)
            out[e["replica"]] = e
        return out
