"""Train-step factory: pjit'd, microbatched (grad accumulation), sharded.

``make_train_step`` returns (jitted_step, state_shardings, batch_shardings).
The state is a plain pytree dict {params, opt{m,v}, step} so the checkpoint
substrate can serialize it without bespoke types.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.layers import use_shard_resolver
from repro.optim import adamw
from repro.parallel.context import use_mesh_context
from repro.parallel.mesh_rules import Rules, batch_logical_axes

tree_map = jax.tree_util.tree_map


def state_logical_axes(cfg: ModelConfig):
    pax = M.param_logical_axes(cfg)
    return {"params": pax, "opt": {"m": pax, "v": pax}, "step": ()}


def predump_boundary(step: int, interval: int, lead: int = 1) -> bool:
    """True when ``step`` is inside the pre-dump window before an interval
    checkpoint: EVERY step in the ``lead`` steps before each boundary fires
    a ``CheckpointManager.precommit`` (iterative pre-copy, CRIU-style).
    Each pre-dump uses the previous one as its fingerprint reference, so
    lead N-1 re-hashes only what dirtied since lead N-2 and the save at the
    boundary pays only for the last step's churn.  ``lead=1`` reproduces
    the single-pre-dump schedule exactly.  ``lead >= interval`` would
    pre-dump a state staler than the previous checkpoint — clamped to
    ``interval - 1``.
    """
    if interval <= 1 or step < 0:
        return False            # interval=1: every step saves; nothing to overlap
    lead = max(1, min(lead, interval - 1))
    r = (-step) % interval      # steps until the next boundary
    return 1 <= r <= lead


def abstract_train_state(cfg: ModelConfig, oc: adamw.OptConfig):
    p = M.abstract_params(cfg)
    mdt = jnp.dtype(oc.moment_dtype)
    mom = tree_map(lambda s: jax.ShapeDtypeStruct(s.shape, mdt), p)
    return {"params": p, "opt": {"m": mom, "v": mom}, "step": jax.ShapeDtypeStruct((), jnp.int32)}


def init_train_state(cfg: ModelConfig, oc: adamw.OptConfig, key) -> dict:
    params = M.init_params(cfg, key)
    return {
        "params": params,
        "opt": adamw.init_opt_state(params, oc),
        "step": jnp.zeros((), jnp.int32),
    }


def state_shardings(cfg: ModelConfig, oc: adamw.OptConfig, rules: Rules):
    ax = state_logical_axes(cfg)
    ab = abstract_train_state(cfg, oc)
    def is_axes_leaf(x):
        return isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)
    return tree_map(
        lambda a, s: rules.sharding(a, s.shape), ax, ab, is_leaf=is_axes_leaf)


def effective_microbatches(global_batch: int, requested: int, batch_shards: int) -> int:
    """Largest M <= requested such that B % M == 0 and each microbatch still
    covers the batch shards (no half-empty DP shards)."""

    def ok(m):
        return global_batch % m == 0 and (global_batch // m) >= min(batch_shards, global_batch)

    for m in range(max(1, min(requested, global_batch)), 0, -1):
        if ok(m):
            return m
    return 1


def make_train_step(cfg: ModelConfig, mesh, oc: adamw.OptConfig, *,
                    microbatches: int = 1, moe_groups: Optional[int] = None,
                    rules: Optional[Rules] = None, impl: Optional[str] = None,
                    accum_dtype: Optional[str] = None, z_loss: float = 1e-4,
                    donate: bool = True):
    rules = rules or Rules(mesh)
    resolver = rules.activation_resolver()
    batch_shards = rules.axis_group_size("batch")
    if moe_groups is None:
        moe_groups = batch_shards
    adt = jnp.dtype(accum_dtype or ("bfloat16" if cfg.param_dtype == "bfloat16" else "float32"))

    def loss_for(params, mb):
        return M.loss_fn(params, cfg, mb, moe_groups=moe_groups, impl=impl, z_loss=z_loss)

    grad_fn = jax.value_and_grad(loss_for, has_aux=True)

    param_sh = state_shardings(cfg, oc, rules)["params"]

    def train_step(state, batch):
        params = state["params"]
        B = batch["tokens"].shape[0]
        mb_count = effective_microbatches(B, microbatches, batch_shards)
        if mb_count == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                return x.reshape((mb_count, B // mb_count) + x.shape[1:])

            mbs = tree_map(split, batch)
            # the accumulator MUST be sharded like the params: an unconstrained
            # zeros carry makes GSPMD materialize full-size gradients and
            # all-reduce them per microbatch (observed: fp32 expert-weight
            # all-reduces dominating the collective term — EXPERIMENTS §Perf i1)
            zero_g = tree_map(
                lambda p, sh: jax.lax.with_sharding_constraint(
                    jnp.zeros(p.shape, adt), sh),
                params, param_sh)

            def body(carry, mb):
                gsum, lsum, ce = carry
                (l, mets), g = grad_fn(params, mb)
                gsum = tree_map(lambda a, b, sh: jax.lax.with_sharding_constraint(
                    a + b.astype(adt), sh), gsum, g, param_sh)
                return (gsum, lsum + l, ce + mets["ce"]), None

            (gsum, lsum, ce), _ = jax.lax.scan(
                body, (zero_g, jnp.zeros(()), jnp.zeros(())), mbs)
            grads = tree_map(lambda g: (g / mb_count).astype(jnp.float32), gsum)
            loss = lsum / mb_count
            metrics = {"ce": ce / mb_count}
        new_p, new_opt, om = adamw.apply_updates(
            params, grads, state["opt"], state["step"], oc)
        new_state = {"params": new_p, "opt": new_opt, "step": state["step"] + 1}
        out_metrics = {"loss": loss, "ce": metrics.get("ce", loss), **om}
        return new_state, out_metrics

    def wrapped(state, batch):
        with use_shard_resolver(resolver), use_mesh_context(mesh, rules):
            return train_step(state, batch)

    st_sh = state_shardings(cfg, oc, rules)
    # batch shardings are resolved per-call shape; expose a helper
    def batch_shardings(batch_like):
        ax = batch_logical_axes(batch_like)
        return {
            k: rules.sharding(ax[k], batch_like[k].shape) for k in batch_like
        }

    jitted = jax.jit(
        wrapped,
        donate_argnums=(0,) if donate else (),
        out_shardings=(st_sh, None),
    )
    return jitted, st_sh, batch_shardings
