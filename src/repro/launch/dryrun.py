"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The first two statements set XLA_FLAGS before ANY jax import — jax locks the
device count on first init.

For each cell this produces, into results/dryrun/<cell>.json:
  - compiled memory_analysis (bytes per device: args/outputs/temps/code)
  - compiled cost_analysis (flops / bytes accessed -- NOTE: scan bodies counted
    once; launch/hlo_costs.py re-walks the HLO multiplying by known_trip_count)
  - trip-count-corrected flops / bytes / per-collective bytes
  - wall compile time

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all [--mesh pod|multipod|both]
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST run before any jax import: jax locks the device count on first init.

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs.base import ARCH_IDS, SHAPES, get_config, shapes_for
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.optim import adamw
from repro.parallel.mesh_rules import Rules, batch_logical_axes

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def build_step(cfg, shape, mesh, *, rules=None, impl=None, microbatches=None,
               moment_dtype=None):
    """Returns (jitted_fn, args_sds, in_shardings)."""
    rules = rules or Rules(mesh)
    kind, args = SP.input_specs(cfg, shape)
    if kind == "train":
        from repro.train.step import make_train_step

        oc = adamw.OptConfig(moment_dtype=moment_dtype or (
            "bfloat16" if cfg.param_dtype == "bfloat16" else "float32"))
        if moment_dtype:
            _, args = SP.input_specs(cfg, shape, oc)  # state dtypes follow oc
        step, st_sh, batch_sh_fn = make_train_step(
            cfg, mesh, oc, rules=rules, impl=impl,
            microbatches=microbatches or SP.train_microbatches(cfg))
        in_sh = (st_sh, batch_sh_fn(args[1]))
        return step, args, in_sh
    if kind == "prefill":
        from repro.serve.engine import make_prefill_step

        step, param_sh, _ = make_prefill_step(
            cfg, mesh, batch=shape.global_batch, seq_len=shape.seq_len,
            rules=rules, impl=impl)
        batch_sh = {
            k: rules.sharding(batch_logical_axes(args[1])[k], v.shape)
            for k, v in args[1].items()
        }
        return step, args, (param_sh, batch_sh)
    if kind == "decode":
        from repro.serve.engine import make_decode_step

        step, param_sh, cache_sh, tok_sh = make_decode_step(
            cfg, mesh, batch=shape.global_batch, max_seq=shape.seq_len,
            rules=rules, donate=False, impl=impl)
        return step, args, (param_sh, cache_sh, tok_sh)
    raise ValueError(kind)


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, save: bool = True,
             hlo_dir=None, tag: str = "", impl=None, microbatches=None,
             moment_dtype=None, rules_overrides=None, cfg_overrides=None) -> dict:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    rules = Rules(mesh, overrides=rules_overrides)
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                 "mesh_shape": list(mesh.devices.shape), "tag": tag,
                 "variant": {"impl": impl, "microbatches": microbatches,
                             "moment_dtype": moment_dtype,
                             "rules_overrides": bool(rules_overrides),
                             "cfg_overrides": cfg_overrides}}
    t0 = time.time()
    try:
        step, args, in_sh = build_step(cfg, shape, mesh, rules=rules, impl=impl,
                                       microbatches=microbatches,
                                       moment_dtype=moment_dtype)
        # attach shardings to the arg specs so donation aliasing is consistent
        args = jax.tree_util.tree_map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            args, in_sh)
        with mesh:
            lowered = step.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_size": getattr(mem, "argument_size_in_bytes", None),
            "output_size": getattr(mem, "output_size_in_bytes", None),
            "temp_size": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size": getattr(mem, "generated_code_size_in_bytes", None),
        }
        ca = compiled.cost_analysis() or {}
        rec["cost_analysis"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        }
        # trip-count-corrected walk of the optimized HLO
        from repro.launch.hlo_costs import analyze_hlo_text

        hlo = compiled.as_text()
        rec["hlo_costs"] = analyze_hlo_text(hlo)
        suffix = f"__{tag}" if tag else ""
        if hlo_dir:
            Path(hlo_dir).mkdir(parents=True, exist_ok=True)
            (Path(hlo_dir) / f"{arch}__{shape_name}__{mesh_kind}{suffix}.hlo"
             ).write_text(hlo)
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record the failure, don't die
        suffix = f"__{tag}" if tag else ""
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 2)
    if save:
        out_dir = RESULTS if not tag else RESULTS.parent / "perf"
        out_dir.mkdir(parents=True, exist_ok=True)
        out = out_dir / f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
        out.write_text(json.dumps(rec, indent=1))
    return rec


def all_cells(mesh_kinds=("pod", "multipod")):
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            for mk in mesh_kinds:
                yield arch, shape.name, mk


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--hlo-dir", default=None)
    # perf-variant knobs (results land in results/perf/<...>__<tag>.json)
    ap.add_argument("--tag", default="")
    ap.add_argument("--attn-impl", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--moment-dtype", default=None)
    ap.add_argument("--cfg-override", action="append", default=[],
                    help="key=value (value eval'd), e.g. remat=dots")
    args = ap.parse_args(argv)
    cfg_overrides = {}
    for kv in args.cfg_override:
        k, v = kv.split("=", 1)
        try:
            cfg_overrides[k] = eval(v)  # noqa: S307 — operator-supplied
        except Exception:
            cfg_overrides[k] = v
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    cells = (
        list(all_cells(meshes)) if args.all
        else [(args.arch, args.shape, mk) for mk in meshes]
    )
    n_ok = 0
    for arch, shape, mk in cells:
        out = RESULTS / f"{arch}__{shape}__{mk}.json"
        if args.skip_done and out.exists() and json.loads(out.read_text()).get("ok"):
            n_ok += 1
            print(f"SKIP {arch} {shape} {mk} (done)")
            continue
        rec = run_cell(arch, shape, mk, hlo_dir=args.hlo_dir, tag=args.tag,
                       impl=args.attn_impl, microbatches=args.microbatches,
                       moment_dtype=args.moment_dtype,
                       cfg_overrides=cfg_overrides or None)
        status = "OK " if rec["ok"] else "FAIL"
        print(f"{status} {arch:24s} {shape:12s} {mk:8s} "
              f"compile={rec.get('compile_s', '-')}s "
              f"{rec.get('error', '')}", flush=True)
        n_ok += int(rec["ok"])
    print(f"{n_ok}/{len(cells)} cells ok")
    return 0 if n_ok == len(cells) else 1


if __name__ == "__main__":
    sys.exit(main())
