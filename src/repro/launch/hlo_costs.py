"""While-loop-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` counts a ``lax.scan`` body ONCE (verified
empirically — see EXPERIMENTS.md §Roofline), which would understate FLOPs and
collective bytes by the trip count everywhere this framework scans (layers,
microbatches, attention blocks).  This module re-walks the optimized HLO:

  * ``while`` ops carry ``backend_config={"known_trip_count":{"n":"N"}}`` —
    bodies are charged N times (nested loops multiply).
  * ``dot``/``convolution`` FLOPs are computed from operand/result shapes.
  * collective bytes (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute, incl. async -start forms) are summed per op kind,
    times the enclosing trip counts.
  * HBM bytes ≈ Σ (operand + result bytes) of materialized ops (fusion
    internals excluded — only fusion boundaries touch HBM).

These are per-*device* numbers: post-SPMD HLO shapes are already the local
shard shapes.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0, "s2": 0.25, "u2": 0.25,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"?n"?[^0-9]*(\d+)')
_OPERAND_RE = re.compile(r"%[\w.\-]+")

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "token", "partition-id", "replica-id", "tuple-select",
    "get-dimension-size", "domain", "opt-barrier",
}


def _type_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Optional[list[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


class _Instr:
    __slots__ = ("name", "type_str", "opcode", "operands", "attrs")

    def __init__(self, name, type_str, opcode, operands, attrs):
        self.name = name
        self.type_str = type_str
        self.opcode = opcode
        self.operands = operands
        self.attrs = attrs


def _balanced(s: str, i: int) -> int:
    """Index just past the balanced paren group starting at s[i] == '('."""
    depth = 0
    for j in range(i, len(s)):
        if s[j] == "(":
            depth += 1
        elif s[j] == ")":
            depth -= 1
            if depth == 0:
                return j + 1
    return len(s)


def _parse_instr(line: str) -> Optional[_Instr]:
    line = line.strip()
    if line.startswith("ROOT "):
        line = line[5:]
    if not line.startswith("%") or " = " not in line:
        return None
    name, rest = line.split(" = ", 1)
    rest = rest.strip()
    if rest.startswith("("):
        end = _balanced(rest, 0)
        type_str, rest = rest[:end], rest[end:].strip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, rest = rest[:sp], rest[sp + 1:].strip()
    m = re.match(r"([\w\-]+)", rest)
    if not m:
        return None
    opcode = m.group(1)
    i = rest.find("(", m.end())
    if i < 0:
        return None
    end = _balanced(rest, i)
    operand_str, attrs = rest[i + 1 : end - 1], rest[end:]
    operands = [o.lstrip("%") for o in _OPERAND_RE.findall(operand_str)]
    return _Instr(name.strip().lstrip("%"), type_str, opcode, operands, attrs)


def _parse_computations(hlo: str) -> tuple[dict, Optional[str], dict]:
    comps: dict[str, list[_Instr]] = {}
    roots: dict[str, str] = {}
    entry = None
    current = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not raw.startswith(" ") and ("{" in line) and ("(" in line):
            m = re.match(r"(ENTRY\s+)?%?([\w.\-]+)\s*\(", line.strip())
            if m:
                current = m.group(2)
                comps[current] = []
                if m.group(1):
                    entry = current
            continue
        if line.strip() == "}":
            continue
        if current is None:
            continue
        is_root = line.strip().startswith("ROOT ")
        ins = _parse_instr(line)
        if ins is not None:
            comps[current].append(ins)
            if is_root:
                roots[current] = ins.name
    return comps, entry, roots


def _dot_flops(ins: _Instr, shapes: dict) -> float:
    out_dims = _shape_dims(ins.type_str) or []
    out_n = 1
    for d in out_dims:
        out_n *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    contract = 1
    if m and ins.operands:
        lhs = shapes.get(ins.operands[0])
        if lhs:
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(lhs):
                    contract *= lhs[int(idx)]
    return 2.0 * out_n * contract


def _conv_flops(ins: _Instr, shapes: dict) -> float:
    out_dims = _shape_dims(ins.type_str) or []
    out_n = 1
    for d in out_dims:
        out_n *= d
    if len(ins.operands) < 2:
        return 0.0
    ker = shapes.get(ins.operands[1]) or []
    ker_n = 1
    for d in ker:
        ker_n *= d
    # flops ~= 2 * out_elems * (kernel_elems / out_features); crude but rare here
    of = out_dims[-1] if out_dims else 1
    return 2.0 * out_n * (ker_n / max(of, 1))


def analyze_hlo_text(hlo: str) -> dict:
    comps, entry, roots = _parse_computations(hlo)
    if entry is None:
        for name in comps:
            if "while" not in name and comps[name]:
                entry = name
                break
    # map computation -> {instr name -> result dims/bytes} for fast lookups
    shape_tables = {
        cname: {i.name: _shape_dims(i.type_str) for i in instrs}
        for cname, instrs in comps.items()
    }
    byte_tables = {
        cname: {i.name: _type_bytes(i.type_str) for i in instrs}
        for cname, instrs in comps.items()
    }

    totals = {"flops": 0.0, "bytes": 0.0, "bytes_native": 0.0, "unknown_while": 0}
    coll = defaultdict(float)
    coll_corr = defaultdict(float)
    coll_instances: list[tuple[float, str]] = []

    def walk(cname: str, mult: float, in_fusion: bool, depth: int = 0):
        if cname not in comps or depth > 64:
            return
        shapes = shape_tables[cname]
        for ins in comps[cname]:
            op = ins.opcode
            if op == "while":
                tm = _TRIP_RE.search(ins.attrs)
                trip = int(tm.group(1)) if tm else 1
                if not tm:
                    totals["unknown_while"] += 1
                body = re.search(r"body=%?([\w.\-]+)", ins.attrs)
                cond = re.search(r"condition=%?([\w.\-]+)", ins.attrs)
                if body:
                    walk(body.group(1), mult * trip, in_fusion, depth + 1)
                if cond:
                    walk(cond.group(1), mult * trip, in_fusion, depth + 1)
                continue
            if op in ("fusion", "call", "async-start"):
                called = re.search(r"calls=%?([\w.\-]+)", ins.attrs) or re.search(
                    r"to_apply=%?([\w.\-]+)", ins.attrs)
                if called:
                    # recurse for flops/collectives; HBM bytes are charged at the
                    # fusion boundary by walk_bytes
                    walk(called.group(1), mult,
                         in_fusion or op == "fusion", depth + 1)
                continue
            if op == "conditional":
                branch_pat = (r"(?:true_computation|false_computation"
                              r"|branch_computations)=\{?%?([\w.\-]+)")
                for mm in re.finditer(branch_pat, ins.attrs):
                    walk(mm.group(1), mult, in_fusion, depth + 1)
                continue
            if op == "dot":
                totals["flops"] += _dot_flops(ins, shapes) * mult
            elif op == "convolution":
                totals["flops"] += _conv_flops(ins, shapes) * mult
            base = op.replace("-start", "")
            if base in COLLECTIVES:
                nbytes = _type_bytes(ins.type_str)
                if base == "all-gather":
                    # charge operand (shard) bytes, per instructions
                    bt = byte_tables[cname]
                    nbytes = sum(bt.get(o, 0.0) for o in ins.operands)
                coll[base] += nbytes * mult
                # CPU-backend artifact: bf16 dots lower as convert->f32 dot, and
                # SPMD reduces the f32 accumulator; on TPU the wire dtype is
                # bf16.  Track the corrected (native-dtype) number separately.
                corr = nbytes
                if "f32[" in ins.type_str and "dot_general" in ins.attrs:
                    corr = nbytes / 2.0
                coll_corr[base] += corr * mult
                coll_instances.append(
                    (nbytes * mult, f"{base} {ins.type_str[:70]} x{mult:g}"))
        return

    # ---- bytes: second pass, boundary-level, slice-aware -------------------
    # In-place patterns must not charge whole buffers: a dynamic-update-slice
    # writes |update| bytes, a dynamic-slice/gather reads |result| bytes — XLA
    # executes scan-carried accumulators in place, so charging the full carry
    # per iteration overstates HBM traffic by orders of magnitude.
    _PASSTHRU = ("bitcast", "copy", "reshape", "transpose", "convert")

    def _fusion_io_bytes(ins, cname) -> float:
        fname_m = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
        bt = byte_tables[cname]
        if not fname_m or fname_m.group(1) not in comps:
            b = _type_bytes(ins.type_str)
            return b + sum(bt.get(o, 0.0) for o in ins.operands)
        fname = fname_m.group(1)
        fcomp = comps[fname]
        fbt = byte_tables[fname]
        defs = {fi.name: fi for fi in fcomp}
        users: dict[str, list] = {}
        for fi in fcomp:
            for o in fi.operands:
                users.setdefault(o, []).append(fi)

        def resolve_param(name, hops=0):
            """Chase bitcast/copy chains back to a parameter name (or None)."""
            d = defs.get(name)
            while d is not None and hops < 8:
                if d.opcode == "parameter":
                    return d.name
                if d.opcode in _PASSTHRU and d.operands:
                    d = defs.get(d.operands[0])
                    hops += 1
                    continue
                return None
            return None

        total = 0.0
        inplace_params: set[str] = set()
        dus_names: set[str] = set()
        for fi in fcomp:
            if fi.opcode in ("dynamic-update-slice", "scatter"):
                upd = (fi.operands[1 if fi.opcode == "dynamic-update-slice" else 2]
                       if len(fi.operands) > 1 else None)
                total += 2 * (fbt.get(upd, 0.0) if upd else _type_bytes(fi.type_str))
                dus_names.add(fi.name)
                p = resolve_param(fi.operands[0]) if fi.operands else None
                if p:
                    inplace_params.add(p)   # buffer is updated in place
        # inputs
        for fi in fcomp:
            if fi.opcode != "parameter" or fi.name in inplace_params:
                continue
            us = users.get(fi.name, [])
            # chase pass-through uses one level (bitcast of param -> slice)
            eff = []
            for u in us:
                if u.opcode in _PASSTHRU:
                    eff.extend(users.get(u.name, []) or [u])
                else:
                    eff.append(u)
            if eff and all(u.opcode in ("dynamic-slice", "gather", "slice")
                           for u in eff):
                total += sum(_type_bytes(u.type_str) for u in eff)
            else:
                total += _type_bytes(fi.type_str)
        # output: skip buffers already counted as in-place DUS writes
        rname = roots.get(fname)
        root = defs.get(rname) if rname else (fcomp[-1] if fcomp else None)

        def out_elem_bytes(name):
            d = defs.get(name)
            hops = 0
            while d is not None and d.opcode in _PASSTHRU and d.operands and hops < 8:
                d = defs.get(d.operands[0])
                hops += 1
            if d is not None and d.name in dus_names:
                return 0.0                       # already charged as slice write
            return fbt.get(name, 0.0)

        if root is None:
            total += _type_bytes(ins.type_str)
        elif root.opcode == "tuple":
            for o in root.operands:
                total += out_elem_bytes(o)
        else:
            total += out_elem_bytes(root.name)
        return total

    byte_instances: list[tuple[float, str]] = []

    def _is_convert_only_fusion(ins) -> bool:
        m = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
        if not m or m.group(1) not in comps:
            return False
        body = [fi for fi in comps[m.group(1)] if fi.opcode != "parameter"]
        return len(body) == 1 and body[0].opcode == "convert"

    def _charge(nbytes: float, ins, cname: str, mult: float):
        totals["bytes"] += nbytes * mult
        # native-dtype (TPU) estimate: bf16 dots don't round-trip through f32
        # buffers on TPU — halve f32 dot outputs, drop pure convert fusions.
        native = nbytes
        if ins.opcode == "fusion" and _is_convert_only_fusion(ins):
            native = 0.0
        elif "f32[" in ins.type_str and "dot_general" in ins.attrs:
            native = nbytes / 2.0
        totals["bytes_native"] += native * mult
        if nbytes * mult > 1e9:
            byte_instances.append(
                (nbytes * mult,
                 f"{cname[:24]}/{ins.opcode} {ins.type_str[:60]} x{mult:g}"))

    def walk_bytes(cname: str, mult: float, depth: int = 0):
        if cname not in comps or depth > 64:
            return
        bt = byte_tables[cname]
        for ins in comps[cname]:
            op = ins.opcode
            if op == "while":
                tm = _TRIP_RE.search(ins.attrs)
                trip = int(tm.group(1)) if tm else 1
                body = re.search(r"body=%?([\w.\-]+)", ins.attrs)
                if body:
                    walk_bytes(body.group(1), mult * trip, depth + 1)
                continue
            if op == "call":
                called = re.search(r"to_apply=%?([\w.\-]+)", ins.attrs)
                if called:
                    walk_bytes(called.group(1), mult, depth + 1)
                continue
            if op in _SKIP_BYTES_OPS or op == "conditional":
                continue
            if op == "fusion":
                _charge(_fusion_io_bytes(ins, cname), ins, cname, mult)
                continue
            if op in ("dynamic-slice", "slice", "gather"):
                _charge(2 * _type_bytes(ins.type_str), ins, cname, mult)
                continue
            if op == "dynamic-update-slice":
                upd = ins.operands[1] if len(ins.operands) > 1 else None
                _charge(2 * bt.get(upd, _type_bytes(ins.type_str)), ins, cname, mult)
                continue
            b = _type_bytes(ins.type_str)
            for o in ins.operands:
                b += bt.get(o, 0.0)
            _charge(b, ins, cname, mult)

    if entry:
        walk(entry, 1.0, False)
        walk_bytes(entry, 1.0)
    totals["collectives"] = dict(coll)
    totals["collective_bytes"] = float(sum(coll.values()))
    totals["collective_bytes_native"] = float(sum(coll_corr.values()))
    coll_instances.sort(reverse=True)
    totals["top_collectives"] = [f"{b:.3e}B {d}" for b, d in coll_instances[:10]]
    byte_instances.sort(reverse=True)
    totals["top_bytes"] = [f"{b:.3e}B {d}" for b, d in byte_instances[:12]]
    return totals


if __name__ == "__main__":
    import sys

    print(json.dumps(analyze_hlo_text(open(sys.argv[1]).read()), indent=1))
