"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

``input_specs`` returns exactly what the corresponding jitted step is lowered
with — no device allocation.  Modality frontends are stubs per the assignment:
llava gets precomputed patch embeddings, musicgen gets codebook token ids.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, TRAIN_MICROBATCHES
from repro.models import model as M
from repro.optim import adamw
from repro.train.step import abstract_train_state


def batch_specs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    tok_shape = (batch, seq, cfg.num_codebooks) if cfg.num_codebooks else (batch, seq)
    out = {"tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32)}
    if cfg.num_image_tokens:
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.num_image_tokens, cfg.d_model), jnp.dtype(cfg.compute_dtype))
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig, oc: adamw.OptConfig | None = None):
    """Returns (kind, args) where args are the SDS positional args of the step."""
    oc = oc or adamw.OptConfig(moment_dtype=(
        "bfloat16" if cfg.param_dtype == "bfloat16" else "float32"))
    if shape.kind == "train":
        state = abstract_train_state(cfg, oc)
        batch = batch_specs(cfg, shape.global_batch, shape.seq_len)
        return "train", (state, batch)
    if shape.kind == "prefill":
        params = M.abstract_params(cfg)
        batch = batch_specs(cfg, shape.global_batch, shape.seq_len)
        return "prefill", (params, batch)
    if shape.kind == "decode":
        params = M.abstract_params(cfg)
        cache, _ = M.cache_specs(cfg, shape.global_batch, shape.seq_len)
        tok_shape = ((shape.global_batch, cfg.num_codebooks) if cfg.num_codebooks
                     else (shape.global_batch,))
        tokens = jax.ShapeDtypeStruct(tok_shape, jnp.int32)
        return "decode", (params, cache, tokens)
    raise ValueError(shape.kind)


def train_microbatches(cfg: ModelConfig) -> int:
    return TRAIN_MICROBATCHES.get(cfg.name, 1)
