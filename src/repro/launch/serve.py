"""Serving driver with pause/migrate/resume (the paper's C/R applied to
inference state).

  python -m repro.launch.serve --arch llama3.2-1b --reduced --batch 4 \
      --prompt-len 12 --gen 24 --snapshot-at 8 --ckpt-dir /tmp/serve

Prefills a batch of synthetic prompts, generates; if --snapshot-at is set,
checkpoints the engine (KV caches + cursors) at that token, rebuilds a fresh
engine, restores, and finishes — printing whether the continuation matched an
unmigrated reference (it must, bit-for-bit).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.store import TieredStore
from repro.configs.base import get_config, reduced as reduce_cfg
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.serve.engine import Engine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--snapshot-at", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_serve")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    mesh = make_host_mesh()
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    shape = ((args.batch, args.prompt_len, cfg.num_codebooks)
             if cfg.num_codebooks else (args.batch, args.prompt_len))
    prompts = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, shape), jnp.int32)}

    def fresh():
        return Engine(cfg, mesh, params, batch=args.batch, max_seq=args.max_seq)

    # reference (no migration)
    ref = fresh()
    ref.prefill(prompts)
    ref_tokens = ref.generate(args.gen)

    if not args.snapshot_at:
        print(f"generated {args.gen} tokens x {args.batch} requests")
        print("request 0:", np.asarray(ref_tokens[0]).ravel()[:16], "...")
        return 0

    eng = fresh()
    eng.prefill(prompts)
    first = eng.generate(args.snapshot_at)
    mgr = CheckpointManager(TieredStore(Path(args.ckpt_dir)))
    host = jax.tree_util.tree_map(np.asarray, eng.snapshot())
    mgr.save(0, host)
    mgr.commit(0)
    del eng
    print(f"snapshotted at token {args.snapshot_at}; migrating...")

    eng2 = fresh()
    restored, _ = mgr.restore(host)
    eng2.restore(jax.tree_util.tree_map(jnp.asarray, restored))
    rest = eng2.generate(args.gen - args.snapshot_at)
    got = np.concatenate([first, rest], axis=1)
    match = np.array_equal(got, ref_tokens)
    print(f"continuation {'MATCHES' if match else 'DIVERGED FROM'} the "
          f"unmigrated reference")
    return 0 if match else 1


if __name__ == "__main__":
    sys.exit(main())
