"""Serving driver: pause/migrate/resume, plus serving-fleet weight-follow
(the paper's C/R applied to inference state, and the chunk fabric applied to
weight distribution).

  python -m repro.launch.serve --arch llama3.2-1b --reduced --batch 4 \
      --prompt-len 12 --gen 24 --snapshot-at 8 --ckpt-dir /tmp/serve

Prefills a batch of synthetic prompts, generates; if --snapshot-at is set,
checkpoints the engine (KV caches + cursors) at that token, rebuilds a fresh
engine, restores, and finishes — printing whether the continuation matched an
unmigrated reference (it must, bit-for-bit).

Fleet mode (``--follow``): the checkpoint prefix holds PARAMETER checkpoints
pushed by a trainer (``CheckpointManager`` + ``registry.announce_push``).
This replica restores the latest push read-only, serves batches, and between
batches polls the push plane, fetches newer weights through the chunk
fabric, and swaps them in at generation boundaries (never mid-decode) with
staleness bounded by ``--max-lag-steps``:

  python -m repro.launch.serve --arch llama3.2-1b --reduced --follow \
      --ckpt-dir /tmp/weights --replica r0 --max-lag-steps 2 --batches 4
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
from repro.checkpoint.store import TieredStore, node_local_tier_roots
from repro.configs.base import get_config, reduced as reduce_cfg
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.sched.cache_registry import REGISTRY_DIRNAME, CacheRegistry
from repro.serve.engine import Engine
from repro.serve.weight_sync import ParamHandle, WeightSyncClient


def follow(args) -> int:
    """Serving-fleet follower: restore the latest pushed weights read-only,
    then serve batches while tracking the push plane.

    Fleet citizenship (PR 8): the follower advertises its fetched chunk
    inventory to the registry (follower cache), so the next replica pulls
    the delta from THIS process instead of the shared tier; a replica past
    ``--max-lag-steps`` DRAINS (refuses new batches, keeps polling, shows
    ``draining`` fleet-wide) and re-admits once it catches up, unless
    ``--on-stale raise`` asks for the fail-out-of-rotation behavior.
    ``--local-root`` mounts the node-local tiers under a private directory
    so many replicas of one host stay isolated (and peer-fetchable);
    ``--pipeline-uploads`` overlaps the device upload of push N with the
    fetch of push N+1."""
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    mesh = make_host_mesh()
    tier_roots = (node_local_tier_roots(Path(args.local_root))
                  if args.local_root else None)
    store = TieredStore(Path(args.ckpt_dir), tier_roots=tier_roots)
    registry = CacheRegistry(Path(args.ckpt_dir) / REGISTRY_DIRNAME)
    mgr = CheckpointManager(
        store,
        CheckpointPolicy(delta=args.delta, restore_workers=args.restore_workers),
        node=args.replica, registry=registry)
    template = jax.tree_util.tree_map(
        np.asarray, M.init_params(cfg, jax.random.PRNGKey(args.seed)))
    steps = mgr.steps()
    if not steps:
        print("no committed weight push found; start the publisher first",
              file=sys.stderr)
        return 1
    to_dev = (lambda t: jax.tree_util.tree_map(jnp.asarray, t))
    host, manifest = mgr.restore(template, promote=False,
                                 follower_cache=True)
    handle = ParamHandle(to_dev(host), step=manifest["step"])
    client = WeightSyncClient(mgr, handle, template, registry=registry,
                              replica=args.replica,
                              max_lag_steps=args.max_lag_steps,
                              to_native=to_dev, on_stale=args.on_stale,
                              pipeline_uploads=args.pipeline_uploads)
    eng = Engine(cfg, mesh, handle, batch=args.batch, max_seq=args.max_seq,
                 sync_client=client)
    rng = np.random.default_rng(args.seed)
    shape = ((args.batch, args.prompt_len, cfg.num_codebooks)
             if cfg.num_codebooks else (args.batch, args.prompt_len))
    print(f"replica {args.replica}: serving step {manifest['step']}")
    for b in range(args.batches):
        client.sync_once()                   # fetch off the request path
        if not eng.admit():                  # staleness gate: DRAIN, not die
            print(f"replica {args.replica}: draining at lag {client.lag()}",
                  file=sys.stderr)
            deadline = time.monotonic() + args.drain_timeout_s
            while not eng.admit():
                if time.monotonic() >= deadline:
                    print(f"replica {args.replica}: drain timed out after "
                          f"{args.drain_timeout_s:.0f}s at lag "
                          f"{client.lag()}", file=sys.stderr)
                    client.close()
                    mgr.close()
                    return 1
                time.sleep(args.poll_s)
                client.sync_once()
            print(f"replica {args.replica}: re-admitted at step "
                  f"{handle.step}")
        prompts = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, shape), jnp.int32)}
        eng.prefill(prompts)                 # boundary: staged push swaps in
        eng.generate(args.gen)
        print(f"batch {b}: served step {handle.step}, "
              f"lag {client.lag()}, swaps {handle.swap_count}, "
              f"swap_stall {handle.last_swap_s * 1e6:.0f}us")
    client.close()
    mgr.close()
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--snapshot-at", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_serve")
    ap.add_argument("--seed", type=int, default=0)
    # fleet follower mode
    ap.add_argument("--follow", action="store_true",
                    help="serve as a weight-sync follower of --ckpt-dir")
    ap.add_argument("--replica", default="r0",
                    help="this replica's name in the registry fleet view")
    ap.add_argument("--max-lag-steps", type=int, default=None,
                    help="staleness bound: force a swap (then drain or "
                         "fail) past this many steps behind the push")
    ap.add_argument("--on-stale", choices=("drain", "raise"),
                    default="drain",
                    help="--follow: past --max-lag-steps, drain and "
                         "re-admit (default) or fail out of rotation")
    ap.add_argument("--drain-timeout-s", type=float, default=60.0,
                    help="--follow: give up on a drain that never "
                         "re-admits after this long")
    ap.add_argument("--poll-s", type=float, default=0.1,
                    help="--follow: push-plane poll interval while "
                         "draining")
    ap.add_argument("--pipeline-uploads", action="store_true",
                    help="--follow: overlap device upload of push N with "
                         "the fetch of push N+1")
    ap.add_argument("--local-root", default=None,
                    help="--follow: private node-local tier root for this "
                         "replica (isolates + peer-exposes its cache)")
    ap.add_argument("--batches", type=int, default=4,
                    help="--follow: request batches to serve before exit")
    ap.add_argument("--delta", action="store_true", default=True,
                    help="--follow: expect delta (chunked) weight pushes")
    ap.add_argument("--restore-workers", type=int, default=0)
    args = ap.parse_args(argv)
    if args.follow:
        return follow(args)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    mesh = make_host_mesh()
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    shape = ((args.batch, args.prompt_len, cfg.num_codebooks)
             if cfg.num_codebooks else (args.batch, args.prompt_len))
    prompts = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, shape), jnp.int32)}

    def fresh():
        return Engine(cfg, mesh, params, batch=args.batch, max_seq=args.max_seq)

    # reference (no migration)
    ref = fresh()
    ref.prefill(prompts)
    ref_tokens = ref.generate(args.gen)

    if not args.snapshot_at:
        print(f"generated {args.gen} tokens x {args.batch} requests")
        print("request 0:", np.asarray(ref_tokens[0]).ravel()[:16], "...")
        return 0

    eng = fresh()
    eng.prefill(prompts)
    first = eng.generate(args.snapshot_at)
    mgr = CheckpointManager(TieredStore(Path(args.ckpt_dir)))
    host = jax.tree_util.tree_map(np.asarray, eng.snapshot())
    mgr.save(0, host)
    mgr.commit(0)
    del eng
    print(f"snapshotted at token {args.snapshot_at}; migrating...")

    eng2 = fresh()
    restored, _ = mgr.restore(host)
    eng2.restore(jax.tree_util.tree_map(jnp.asarray, restored))
    rest = eng2.generate(args.gen - args.snapshot_at)
    got = np.concatenate([first, rest], axis=1)
    match = np.array_equal(got, ref_tokens)
    print(f"continuation {'MATCHES' if match else 'DIVERGED FROM'} the "
          f"unmigrated reference")
    return 0 if match else 1


if __name__ == "__main__":
    sys.exit(main())
