"""End-to-end training driver with first-class checkpoint-restart.

This is the job script of the paper's Fig. 3, as a framework CLI:

  python -m repro.launch.train --arch qwen2-0.5b --reduced --steps 200 \\
      --batch 8 --seq 128 --ckpt-dir /tmp/run1 --interval-steps 25 \\
      --walltime 300 --margin 10

Behaviour:
  * restores the latest committed checkpoint if one exists (else cold start);
  * checkpoints every --interval-steps, on trapped SIGTERM/SIGUSR1, and when
    the walltime margin is reached;
  * exits with code 85 (REQUEUE_EXIT) when interrupted mid-run so the batch
    scheduler (sched/slurmsim.py or a real Slurm wrapper) requeues it;
  * optionally attaches to an external checkpoint coordinator
    (--coordinator host:port --worker-id N) for multi-worker rounds.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

import jax

from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
from repro.checkpoint.store import TieredStore, node_local_tier_roots
from repro.configs.base import get_config, reduced as reduce_cfg
from repro.core.cr_manager import CRManager
from repro.core.requeue import RequeueFile, WalltimeTracker, detect_node
from repro.sched.cache_registry import (ENV_PEER_ROOTS, REGISTRY_DIRNAME,
                                        CacheRegistry, parse_peer_roots)
from repro.core.signals import SignalTrap
from repro.core.worker import CkptClient, InlineCoordinator
from repro.data.pipeline import PipelineState, SyntheticTokens
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.parallel.mesh_rules import Rules
from repro.train import step as TS

REQUEUE_EXIT = 85


def build_argparser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--ckpt-mode", default="sync", choices=["sync", "async"])
    ap.add_argument("--ckpt-incremental", action="store_true")
    ap.add_argument("--ckpt-delta", action="store_true",
                    help="content-addressed delta checkpoints (shard v3): "
                         "each save writes only the chunks whose hash "
                         "changed since the parent step, and restores "
                         "fetch only chunks the node is missing")
    ap.add_argument("--ckpt-rebase-every", type=int, default=8,
                    help="delta-chain length bound: after this many chained "
                         "delta commits the manifest re-baselines (chunk "
                         "dedup makes the rebaseline itself free)")
    ap.add_argument("--ckpt-replicas", type=int, default=1)
    ap.add_argument("--ckpt-promote", default="off",
                    choices=["off", "on_restore", "eager"],
                    help="tee restored/committed checkpoints into the "
                         "node-local tier so the next restart on this node "
                         "skips the shared filesystem")
    ap.add_argument("--ckpt-promote-tier", default="local",
                    choices=["ram", "local"])
    ap.add_argument("--local-root", default=None,
                    help="node-local tier root: mounts the local/ram tiers "
                         "under this path instead of --ckpt-dir, so promoted "
                         "caches are per-node (defaults to $REPRO_LOCAL_ROOT "
                         "as set by sched/slurmsim.py placements)")
    ap.add_argument("--peer-roots", default=None,
                    help="warm-peer cache roots as 'name=path,name=path': "
                         "a cold-node restore sources checkpoint ranges from "
                         "these peers' local tiers instead of the shared "
                         "filesystem (defaults to $REPRO_PEER_ROOTS as set "
                         "by the scheduler, then to the last requeue "
                         "record's peer_roots)")
    ap.add_argument("--restore-workers", type=int, default=0,
                    help="parallel restore read pool size (0=auto, 1=serial)")
    ap.add_argument("--hash-workers", type=int, default=0,
                    help="parallel chunk hash/CRC pool size for delta saves "
                         "(0=auto / $REPRO_HASH_WORKERS, 1=serial)")
    ap.add_argument("--ckpt-compress", type=int, default=0,
                    help="per-chunk compression level for delta chunk files "
                         "(0=off; >=1 frames each stored chunk with zstd "
                         "when available, else zlib — hashes stay over the "
                         "raw bytes, so dedup and fingerprints are "
                         "unaffected)")
    ap.add_argument("--io-batch", type=int, default=0,
                    help="ranges per batched restore-read submission "
                         "(0=auto / $REPRO_IO_BATCH, 1=per-range reads)")
    ap.add_argument("--ckpt-fingerprint", action="store_true",
                    help="delta saves stamp per-chunk 32-bit fingerprints "
                         "and use the parent step's as a dirty-chunk "
                         "pre-filter: fingerprint-equal chunks skip blake2b "
                         "(opt-in: a dirty chunk colliding on 32 bits would "
                         "be treated as clean)")
    ap.add_argument("--ckpt-predump", action="store_true",
                    help="CRIU-style pre-dump: before each interval "
                         "checkpoint, snapshot + hash + pre-write chunks in "
                         "the background so the save stall covers only "
                         "bytes dirtied in the last --ckpt-predump-lead "
                         "steps (requires --ckpt-delta)")
    ap.add_argument("--ckpt-predump-lead", type=int, default=1,
                    help="pre-dump window: a pre-dump fires at EVERY step "
                         "in the last N steps before the interval boundary "
                         "(iterative pre-copy — each lead re-hashes only "
                         "what dirtied since the lead before)")
    ap.add_argument("--ckpt-device-fp", action="store_true",
                    help="device-resident dirty detection: run the "
                         "fingerprint kernel on live device params and copy "
                         "only fp-dirty chunks host-side — clean chunks "
                         "cost zero device->host bytes (requires "
                         "--ckpt-delta; set REPRO_DEVICE_FP_IMPL to pick "
                         "the kernel impl)")
    ap.add_argument("--ckpt-calibrate", action="store_true",
                    help="measure per-tier store bandwidth/latency at "
                         "startup (cached in tier_profile.json) and apply "
                         "the profile to tier routing")
    ap.add_argument("--interval-steps", type=int, default=0)
    ap.add_argument("--walltime", type=float, default=0.0)
    ap.add_argument("--margin", type=float, default=5.0)
    ap.add_argument("--coordinator", default=None, help="host:port")
    ap.add_argument("--worker-id", type=int, default=0)
    ap.add_argument("--num-workers", type=int, default=1)
    ap.add_argument("--metrics-out", default=None)
    ap.add_argument("--step-sleep", type=float, default=0.0,
                    help="artificial per-step delay (benchmark pacing)")
    return ap


def main(argv=None) -> int:
    args = build_argparser().parse_args(argv)
    if args.ckpt_delta and args.ckpt_incremental:
        sys.exit("--ckpt-delta and --ckpt-incremental are mutually exclusive")
    if ((args.ckpt_predump or args.ckpt_fingerprint or args.ckpt_device_fp)
            and not args.ckpt_delta):
        sys.exit("--ckpt-predump/--ckpt-fingerprint/--ckpt-device-fp "
                 "require --ckpt-delta")
    # trap preemption signals from the very start: a USR1 during jit compile /
    # restore must checkpoint-and-requeue, not kill the process (default USR1
    # action is terminate) — the paper's startup-time lesson (Fig. 2) applies
    # to the C/R loop itself.
    trap = SignalTrap()
    trap.__enter__()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    oc = adamw.OptConfig(lr=args.lr, warmup_steps=10, decay_steps=max(args.steps, 2))

    mesh = make_host_mesh()
    rules = Rules(mesh)
    jitted, st_sh, batch_sh_fn = TS.make_train_step(
        cfg, mesh, oc, microbatches=args.microbatches, rules=rules, donate=False)

    # multi-node placement: the shared tier lives under --ckpt-dir for every
    # node; the node-LOCAL tiers mount under the root the scheduler handed us,
    # so a shared->local promotion warms exactly this node's cache and the
    # restore-aware scheduler can route the next requeue back here.
    local_root = args.local_root or os.environ.get("REPRO_LOCAL_ROOT")
    tier_roots = node_local_tier_roots(local_root) if local_root else None
    store = TieredStore(Path(args.ckpt_dir), tier_roots=tier_roots)
    if args.ckpt_calibrate:
        # measured tier profile (cached in tier_profile.json under the store
        # root) replaces the static tier table — restore sizing and promote
        # routing then reflect THIS machine's actual I/O planes
        from repro.checkpoint.calibrate import calibrate_tiers
        calibrate_tiers(store)
    requeue_file = RequeueFile(Path(args.ckpt_dir) / "requeue.json")
    prior = requeue_file.load()
    # peer fabric: scheduler hint first, then whatever the last attempt
    # recorded; the registry adds decentralized discovery on top
    node = detect_node()
    peers = parse_peer_roots(args.peer_roots
                             or os.environ.get(ENV_PEER_ROOTS))
    if not peers:
        peers = {n: Path(r)
                 for n, r in (prior.get("peer_roots") or {}).items()}
    registry = CacheRegistry(
        Path(args.ckpt_dir) / REGISTRY_DIRNAME)
    policy = CheckpointPolicy(replicas=args.ckpt_replicas,
                              mode=args.ckpt_mode,
                              incremental=args.ckpt_incremental,
                              delta=args.ckpt_delta,
                              rebase_every=args.ckpt_rebase_every,
                              restore_workers=args.restore_workers,
                              fingerprint=args.ckpt_fingerprint,
                              device_fp=args.ckpt_device_fp,
                              hash_workers=args.hash_workers,
                              compress=args.ckpt_compress,
                              io_batch=args.io_batch,
                              promote=args.ckpt_promote,
                              promote_tier=args.ckpt_promote_tier)
    ckpt = CheckpointManager(store, policy, worker_id=args.worker_id,
                             num_workers=args.num_workers, peer_roots=peers,
                             node=node, registry=registry)

    if args.coordinator:
        host, port = args.coordinator.rsplit(":", 1)
        client = CkptClient(host, int(port), args.worker_id)
    else:
        client = InlineCoordinator(commit_fn=ckpt.commit)

    walltime = None
    if args.walltime:
        walltime = WalltimeTracker(args.walltime, args.margin,
                                   consumed_s=prior.get("consumed_s", 0.0))

    pipe = SyntheticTokens(cfg, args.batch, args.seq, seed=args.seed)

    try:
        crm = CRManager(ckpt, client=client, signal_trap=trap, walltime=walltime,
                        requeue_file=requeue_file,
                        interval_steps=args.interval_steps or None,
                        predump=args.ckpt_predump,
                        predump_lead=args.ckpt_predump_lead,
                        cfg=cfg, rules=rules, node=node,
                        peers=peers or None)

        def init_fn():
            return TS.init_train_state(cfg, oc, jax.random.PRNGKey(args.seed))

        # template for restore: abstract state (host arrays will be placed in)
        templates = {"state": TS.abstract_train_state(cfg, oc)}
        axes = {"state": TS.state_logical_axes(cfg)}
        state, meta, start_step = crm.restore_or_init(init_fn, templates, axes)
        if meta is not None and "data_state" in meta:
            pipe.restore(PipelineState.from_dict(meta["data_state"]))

        metrics_log = []
        exit_code = 0
        step = start_step
        for step in range(start_step, args.steps):
            batch = next(pipe)
            state, metrics = jitted(state, batch)
            if args.step_sleep:
                time.sleep(args.step_sleep)
            loss = float(metrics["loss"])
            metrics_log.append({"step": step, "loss": loss,
                                "t": time.time()})
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step} loss {loss:.4f}", flush=True)

            extra = {"data_state": pipe.state().to_dict()}
            action = crm.step_boundary(step, lambda: state, extra_meta=extra)
            if action == "exit":
                crm.request_requeue(step, reason=crm.exit_reason() or "")
                print(f"[train] interrupted at step {step} -> requeue", flush=True)
                exit_code = REQUEUE_EXIT
                break
        else:
            # run completed: final checkpoint so eval/serving can pick it up
            crm.checkpoint_now(args.steps - 1, lambda: state, reason="final",
                               extra_meta={"data_state": pipe.state().to_dict(),
                                           "completed": True})
            print(f"[train] completed {args.steps} steps", flush=True)

        if args.metrics_out:
            Path(args.metrics_out).write_text(json.dumps(metrics_log))
        crm.close()
    finally:
        trap.__exit__(None, None, None)
    return exit_code


if __name__ == "__main__":
    sys.exit(main())
