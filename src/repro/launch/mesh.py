"""Production mesh construction.

A function (not a module-level constant) so importing this module never touches
jax device state.  The dry-run sets XLA_FLAGS host-device-count *before* any jax
import; everything else sees the real device count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally, as a (data, model) mesh with model=1."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
