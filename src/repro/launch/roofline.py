"""Roofline analysis over the dry-run artifacts (TPU v5e targets).

Reads results/dryrun/<arch>__<shape>__<mesh>.json (produced by launch/dryrun.py)
and derives, per cell:

    compute term    = FLOPs_per_device / 197e12            [s]
    memory term     = HBM_bytes_per_device / 819e9         [s]
    collective term = collective_bytes_per_device / 50e9   [s]

FLOPs / bytes / collective bytes come from the trip-count-corrected HLO walk
(launch/hlo_costs.py) because ``compiled.cost_analysis()`` counts scan bodies
once.  All quantities are per-device (post-SPMD local shapes), so the "/chips"
in the assignment's formulas is already applied.

MODEL_FLOPS uses the classic estimator per shape kind (per device):
    train:   6 * N_active * tokens / chips
    prefill: 2 * N_active * tokens / chips
    decode:  2 * N_active * batch  / chips   (one new token per sequence)

useful_fraction = ideal compute time / max(term): the fraction of the
bottleneck-limited step that would be useful model FLOPs at peak — the score
§Perf hillclimbs.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs.base import SHAPES, get_config

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

RESULTS = Path(__file__).resolve().parents[3] / "results"


def model_flops_per_device(arch: str, shape_name: str, chips: int) -> float:
    from repro.models.model import count_active_params

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = count_active_params(cfg)
    if shape.kind == "train":
        total = 6.0 * n * shape.tokens
    elif shape.kind == "prefill":
        total = 2.0 * n * shape.tokens
    else:  # decode: one token per sequence
        total = 2.0 * n * shape.global_batch
    return total / chips


def decode_min_bytes_per_device(arch: str, shape_name: str, chips: int) -> float:
    """Decode ideal: every active-param byte + every live cache byte read once
    per token — the true decode roofline is HBM, not FLOPs."""
    from repro.models.model import cache_specs, count_active_params

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    pbytes = count_active_params(cfg) * (2 if cfg.param_dtype == "bfloat16" else 4)
    sds, _ = cache_specs(cfg, shape.global_batch, shape.seq_len)
    import numpy as np

    cbytes = sum(int(np.prod(s.shape)) * s.dtype.itemsize
                 for s in __import__("jax").tree_util.tree_leaves(sds)
                 if hasattr(s, "shape"))
    return (pbytes + cbytes) / chips


def analyze_cell(rec: dict) -> dict:
    chips = 1
    for d in rec["mesh_shape"]:
        chips *= d
    hc = rec["hlo_costs"]
    compute_s = hc["flops"] / PEAK_FLOPS
    # native-dtype estimates (TPU target) preferred; raw CPU-lowering numbers
    # retained in the record (see hlo_costs.py on the f32-accumulator artifact)
    memory_s = hc.get("bytes_native", hc["bytes"]) / HBM_BW
    collective_s = hc.get("collective_bytes_native",
                          hc["collective_bytes"]) / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"], chips)
    if SHAPES[rec["shape"]].kind == "decode":
        ideal_s = decode_min_bytes_per_device(rec["arch"], rec["shape"], chips) / HBM_BW
    else:
        ideal_s = mf / PEAK_FLOPS
    frac = ideal_s / max(max(terms.values()), 1e-30)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s,
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": hc["flops"],
        "useful_ratio": mf / max(hc["flops"], 1e-30),
        "useful_fraction": frac,
        "collectives": hc.get("collectives", {}),
        "temp_bytes": rec.get("memory", {}).get("temp_size"),
        "arg_bytes": rec.get("memory", {}).get("argument_size"),
    }


_SUGGEST = {
    "compute": "cut non-model FLOPs: remat policy (dots_saveable), avoid "
               "replicated attention (shard heads/seq), fuse MTP/loss work",
    "memory": "reduce HBM traffic: larger microbatches amortize weight reads, "
              "bf16 activations, fewer remat recomputes, fuse normalizations",
    "collective": "reshard: move FSDP all-gathers off the critical path "
                  "(overlap), 2D-shard params, reduce-scatter grads instead of "
                  "all-reduce, shrink MoE all-to-all via capacity tuning",
}


def render_table(cells: list[dict], mesh: str = "pod") -> str:
    rows = [c for c in cells if c["mesh"] == mesh]
    rows.sort(key=lambda c: (c["arch"], c["shape"]))
    out = ["| arch | shape | compute s | memory s | collective s | dominant | "
           "6ND/HLO | useful frac | what would move the dominant term |",
           "|---|---|---|---|---|---|---|---|---|"]
    for c in rows:
        out.append(
            f"| {c['arch']} | {c['shape']} | {c['compute_s']:.3e} | "
            f"{c['memory_s']:.3e} | {c['collective_s']:.3e} | {c['dominant']} | "
            f"{c['useful_ratio']:.2f} | {c['useful_fraction']:.3f} | "
            f"{_SUGGEST[c['dominant']][:60]}… |")
    return "\n".join(out)


def load_cells(dryrun_dir: Path) -> list[dict]:
    cells = []
    for f in sorted(dryrun_dir.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("ok") and "hlo_costs" in rec:
            cells.append(analyze_cell(rec))
    return cells


def reanalyze(dryrun_dir: Path, hlo_dir: Path) -> int:
    """Re-parse saved HLO dumps with the current cost model (no recompiles)."""
    from repro.launch.hlo_costs import analyze_hlo_text

    n = 0
    for f in sorted(dryrun_dir.glob("*.json")):
        rec = json.loads(f.read_text())
        tag = f"__{rec['tag']}" if rec.get("tag") else ""
        hlo = hlo_dir / f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{tag}.hlo"
        if rec.get("ok") and hlo.exists():
            rec["hlo_costs"] = analyze_hlo_text(hlo.read_text())
            f.write_text(json.dumps(rec, indent=1))
            n += 1
    return n


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default=str(RESULTS / "dryrun"))
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--out", default=str(RESULTS / "roofline.json"))
    ap.add_argument("--reanalyze-hlo", default=None,
                    help="re-parse saved HLO dumps with the current cost model")
    args = ap.parse_args(argv)
    if args.reanalyze_hlo:
        n = reanalyze(Path(args.dryrun_dir), Path(args.reanalyze_hlo))
        print(f"re-analyzed {n} cells from saved HLO")
    cells = load_cells(Path(args.dryrun_dir))
    Path(args.out).write_text(json.dumps(cells, indent=1))
    print(render_table(cells, args.mesh))
    picks = sorted((c for c in cells if c["mesh"] == args.mesh),
                   key=lambda c: c["useful_fraction"])
    if picks:
        print("\nworst useful_fraction:",
              [(c["arch"], c["shape"], round(c["useful_fraction"], 4))
               for c in picks[:3]])
        coll = sorted((c for c in cells if c["mesh"] == args.mesh),
                      key=lambda c: -c["collective_s"] /
                      max(c["compute_s"] + c["memory_s"], 1e-30))
        print("most collective-bound:",
              [(c["arch"], c["shape"]) for c in coll[:3]])


if __name__ == "__main__":
    main()
